"""Pallas TPU kernel: fused LiGO depth-blend + width-expansion.

Computes ``P[l2] = B @ (Σ_l w[l2, l] · W[l])`` — the growth hot-spot. The
torch reference implementation materialises the widened stack (L1, D2, D2) in
HBM and then blends along depth; on TPU we exploit that the blend commutes
with the (layer-independent) width expansion and fuse the blend into the
matmul's rhs operand load:

- grid ``(L2, i, b, a)`` over output-row tiles × small-dim tiles, the ``a``
  (contraction) dimension innermost with an accumulating output block;
- per grid step the kernel loads the (L1, TA, TB) slab of the *small* weight
  stack into VMEM, blends it with the ``w[l2]`` row (a vector FMA, VPU work
  overlapped with the MXU matmul), and feeds the blended (TA, TB) tile
  straight to the MXU — the blended stack never exists in HBM.

HBM traffic: L2·(D1o·D1i)·(D2o/TI) reads of W + output writes, vs the naive
order's extra L1·D2o·D2i intermediate write+read. Tiles are 128-aligned for
the MXU. Validated in interpret mode against ref.ligo_blend_expand_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(w_ref, b_ref, W_ref, out_ref, acc_ref, *, n_a: int, L1: int):
    a = pl.program_id(3)

    @pl.when(a == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # blend the small stack slab with this l2's depth weights: (TA, TB)
    w_row = w_ref[0]                                     # (L1,)
    slab = W_ref[...]                                    # (L1, TA, TB)
    blended = jax.lax.dot_general(
        w_row[None, :], slab.reshape(L1, -1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(slab.shape[1], slab.shape[2])
    # expand: (TI, TA) @ (TA, TB) -> (TI, TB)
    acc_ref[...] += jax.lax.dot(
        b_ref[...].astype(jnp.float32), blended,
        preferred_element_type=jnp.float32)

    @pl.when(a == n_a - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ti", "ta", "tb", "interpret"))
def ligo_blend_expand(w: jax.Array, B: jax.Array, W: jax.Array, *,
                      ti: int = 128, ta: int = 128, tb: int = 128,
                      interpret: bool = False) -> jax.Array:
    """w: (L2, L1); B: (D2o, D1o); W: (L1, D1o, D1i) → (L2, D2o, D1i)."""
    L2, L1 = w.shape
    D2o, D1o = B.shape
    _, _, D1i = W.shape
    assert W.shape[0] == L1 and W.shape[1] == D1o
    ti, ta, tb = min(ti, D2o), min(ta, D1o), min(tb, D1i)
    assert D2o % ti == 0 and D1o % ta == 0 and D1i % tb == 0, \
        (D2o, ti, D1o, ta, D1i, tb)
    n_i, n_a, n_b = D2o // ti, D1o // ta, D1i // tb

    grid = (L2, n_i, n_b, n_a)
    kernel = functools.partial(_kernel, n_a=n_a, L1=L1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L1), lambda l2, i, b, a: (l2, 0)),
            pl.BlockSpec((ti, ta), lambda l2, i, b, a: (i, a)),
            pl.BlockSpec((L1, ta, tb), lambda l2, i, b, a: (0, a, b)),
        ],
        out_specs=pl.BlockSpec((1, ti, tb), lambda l2, i, b, a: (l2, i, b)),
        out_shape=jax.ShapeDtypeStruct((L2, D2o, D1i), B.dtype),
        scratch_shapes=[pltpu.VMEM((ti, tb), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(w.astype(jnp.float32), B, W)
