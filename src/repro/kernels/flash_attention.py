"""Pallas TPU kernel: flash attention (causal / sliding-window / bidirectional),
GQA-native.

Online-softmax with (m, l, acc) VMEM scratch carried across the kv grid
dimension. GQA needs no KV repeat in HBM: the K/V BlockSpec index maps query
head ``h`` to kv head ``h // G`` — the broadcast happens in the VMEM copy.
Tiles default to (128 q × 128 k) — MXU-aligned; scores/accumulation fp32.

q: (B, H, T, dh); k, v: (B, KV, S, dh). Causal alignment: the last q row
attends to the last k row (prefill/training layout).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, n_k: int,
            tq: int, tk: int, t_offset: int):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (TQ, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (TK, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qb = pl.program_id(2)
    qpos = qb * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0) \
        + t_offset
    kpos = kb * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = jnp.ones((tq, tk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == n_k - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "tq", "tk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    tq: int = 128, tk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Returns (B, H, T, dh); see module docstring for layout."""
    B, H, T, dh = q.shape
    _, KV, S, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    tq, tk = min(tq, T), min(tk, S)
    assert T % tq == 0 and S % tk == 0, (T, tq, S, tk)
    n_q, n_k = T // tq, S // tk
    scale = 1.0 / math.sqrt(dh)
    t_offset = S - T       # causal alignment: last q row ↔ last k row

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, n_k=n_k,
        tq=tq, tk=tk, t_offset=t_offset)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, tq, dh), lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, tk, dh),
                         lambda b, h, qb, kb: (b, h // G, kb, 0)),
            pl.BlockSpec((1, 1, tk, dh),
                         lambda b, h, qb, kb: (b, h // G, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, dh),
                               lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
