"""Version shims for the Pallas TPU API across jax releases.

jax renamed ``pltpu.TPUCompilerParams`` (≤ 0.4.x) to ``pltpu.CompilerParams``
(newer releases). Kernels import :func:`tpu_compiler_params` instead of
touching either name directly so the same source compiles everywhere.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params struct under whichever name jax exports.

    Accepts the keyword args shared by both APIs (``dimension_semantics``,
    ``vmem_limit_bytes``, ...).
    """
    return _COMPILER_PARAMS_CLS(**kwargs)
