"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ligo_blend_expand_ref(w: jax.Array, B: jax.Array, W: jax.Array
                          ) -> jax.Array:
    """P[l2] = B @ (Σ_l w[l2,l] · W[l]).

    w: (L2, L1); B: (D2o, D1o); W: (L1, D1o, D1i) → (L2, D2o, D1i).
    (Depth-blend commutes with width-expansion — both are linear and the width
    operator is layer-independent — so blending in the *small* space first is
    both the reference semantics and the kernel's fusion opportunity.)
    """
    blended = jnp.einsum("kl,lab->kab", w, W)
    return jnp.einsum("ia,kab->kib", B, blended)


def ligo_blend_expand_grouped_ref(w: jax.Array, B: jax.Array, W: jax.Array
                                  ) -> jax.Array:
    """Grouped oracle: P[g,k,e] = B @ (Σ_l w[g,k,l] · W[g,l,e]).

    w: (G, L2, L1); B: (I, A); W: (G, L1, E, A, Bd) → (G, L2, E, I, Bd).
    Accumulates in float32 (``preferred_element_type``) while streaming the
    operands at their storage dtype — the CPU/interpret-mode ground truth for
    the fused forward kernel.
    """
    blended = jnp.einsum("gkl,gleab->gkeab", w, W,
                         preferred_element_type=jnp.float32)
    return jnp.einsum("ia,gkeab->gkeib", B, blended,
                      preferred_element_type=jnp.float32).astype(B.dtype)


def ligo_blend_expand_bwd_ref(w: jax.Array, B: jax.Array, W: jax.Array,
                              dP: jax.Array):
    """Einsum oracle for the fused backward: transpose of the grouped
    blend-expand without widened intermediates.

    - T[g,k,e] = Bᵀ dP[g,k,e]          (small-space (A, Bd) stack)
    - dW[g,l,e] = Σ_k w[g,k,l] T[g,k,e]
    - dB = Σ_{g,k,e} dP[g,k,e] · blendedᵀ   (blended = w·W, small space)
    - dw[g,k,l] = Σ_e ⟨T[g,k,e], W[g,l,e]⟩

    All contractions accumulate in float32 via ``preferred_element_type`` but
    stream ``dP``/``W`` at param dtype (no HBM-doubling upcast for bf16
    trees). Returns (dw, dB, dW) cast to the operand dtypes.
    """
    f32 = jnp.float32
    T = jnp.einsum("ia,gkeib->gkeab", B, dP, preferred_element_type=f32)
    dW = jnp.einsum("gkl,gkeab->gleab", w, T,
                    preferred_element_type=f32).astype(W.dtype)
    blended = jnp.einsum("gkl,gleab->gkeab", w, W,
                         preferred_element_type=f32)
    dB = jnp.einsum("gkeib,gkeab->ia", dP, blended,
                    preferred_element_type=f32).astype(B.dtype)
    dw = jnp.einsum("gkeab,gleab->gkl", T, W,
                    preferred_element_type=f32).astype(w.dtype)
    return dw, dB, dW


def ligo_expand_full_ref(w, B, A, W):
    """Full fused growth Ω[l2] = B (Σ_l w[l2,l] W_l) Aᵀ — oracle for ops."""
    P = ligo_blend_expand_ref(w, B, W)
    return jnp.einsum("kib,jb->kij", P, A)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0
                        ) -> jax.Array:
    """Naive full-matrix attention, fp32 softmax.

    q: (B, H, T, dh); k, v: (B, KV, S, dh), H % KV == 0. Returns (B, H, T, dh).
    """
    Bb, H, T, dh = q.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / jnp.sqrt(dh).astype(jnp.float32)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos + (S - T) >= kpos       # align last q with last k
    if window:
        mask &= kpos > qpos + (S - T) - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, vv).astype(q.dtype)
