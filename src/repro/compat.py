"""Version shims for jax sharding APIs that moved between 0.4.x and 0.5+.

Newer jax exposes ``jax.sharding.AxisType``, ``jax.set_mesh`` and
``jax.shard_map``; jax 0.4.37 (this container) predates all three. Code and
tests import the equivalents from here so one source tree runs on both:

- :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` when supported,
  plain ``jax.make_mesh`` otherwise (0.4.x meshes are implicitly "auto").
- :func:`set_mesh` — ``jax.set_mesh(mesh)`` context manager when available;
  on 0.4.x the ``Mesh`` object itself is the context manager.
- :func:`shard_map` — ``jax.shard_map`` or the 0.4.x
  ``jax.experimental.shard_map.shard_map``, translating the ``check_vma``
  kwarg to its old name ``check_rep``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Device mesh with auto axis types on every jax version."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``with set_mesh(mesh): ...`` works on both old and new jax.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax.sharding.Mesh is its own context manager on 0.4.x


def get_mesh():
    """The ambient mesh installed by :func:`set_mesh`, or None.

    New jax: ``jax.sharding.get_abstract_mesh()``. 0.4.x: the thread-local
    physical mesh set by the ``Mesh`` context manager.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        m = jax.sharding.get_abstract_mesh()
        return None if m is None or getattr(m, "empty", True) else m
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m is None or getattr(m, "empty", True) else m


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` across versions (``check_vma`` ↔ ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
