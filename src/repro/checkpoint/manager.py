"""CheckpointManager: async writes, retention, elastic restore.

- ``save(step, tree, meta)``: snapshot to host (cheap device_get) then write
  on a background thread; the train loop never blocks on disk.
  ``snapshot="device"`` instead enqueues an async device-to-device copy and
  moves the device→host transfer onto the write thread too — the elastic
  LiGO phase uses it to keep chunk-boundary checkpoints off the critical
  path.
- retention: keep the newest ``keep`` checkpoints.
- ``restore_latest(template, shardings=None)``: loads into any mesh — arrays
  are ``jax.device_put`` with the *target* sharding, so a job checkpointed on
  N devices restarts on M devices (elastic scaling / shrunk-fleet recovery).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import io

Params = Any


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Params, meta: Optional[Dict] = None,
             *, block: bool = False, snapshot: str = "host") -> None:
        """``snapshot`` picks how the tree is pinned before the async write:

        - ``"host"`` (default): synchronous device→host copy up front — the
          caller can mutate or donate the tree the moment ``save`` returns,
          but the critical path pays the full transfer.
        - ``"device"``: double-buffered async device-to-device snapshot —
          ``jnp.copy`` only *enqueues* the copy, so the critical path
          resumes immediately; the device→host transfer and flatten happen
          on the write thread. The copy is ordered before any later op that
          touches the source buffers (single device stream), so the bytes
          written are exactly the bytes at call time — kill+resume
          bit-equality is preserved. ``wait()`` (called at the top of the
          next save) retires the previous snapshot buffer.
        """
        assert snapshot in ("host", "device"), snapshot
        self.wait()                          # one write in flight at a time
        if snapshot == "device":
            import jax.numpy as jnp
            snap = jax.tree.map(jnp.copy, tree)
            payload = lambda: io.flatten_tree(snap)  # noqa: E731
        else:
            host_flat = io.flatten_tree(tree)  # sync device->host snapshot
            payload = lambda: host_flat        # noqa: E731

        def write():
            try:
                import os
                import shutil
                io.save_step(self.dir, step, payload(), meta)
                steps = io.list_steps(self.dir)
                for s in steps[:-self.keep]:
                    shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                                  ignore_errors=True)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_write and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = io.list_steps(self.dir)
        return steps[-1] if steps else None

    def latest_meta(self) -> Optional[Dict]:
        """Meta dict of the newest checkpoint, arrays untouched.

        Mid-trajectory resumes peek this first: the stage index / config
        identity recorded at save time decides which architecture's template
        (and which mesh shardings) ``restore`` is then called with.
        """
        step = self.latest_step()
        if step is None:
            return None
        return io.load_meta(self.dir, step)

    def restore(self, step: int, template: Params,
                shardings: Optional[Params] = None
                ) -> Tuple[Params, Dict]:
        flat, meta = io.load_step(self.dir, step)
        tree = io.unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda arr, t: jax.numpy.asarray(arr, dtype=t.dtype),
                tree, template)
        return tree, meta

    def restore_latest(self, template: Params,
                       shardings: Optional[Params] = None
                       ) -> Optional[Tuple[Params, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, template, shardings)
