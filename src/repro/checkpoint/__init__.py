from repro.checkpoint.io import (flatten_tree, list_steps, load_meta,
                                 load_step, save_step, unflatten_into)
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager", "save_step", "load_step", "load_meta",
           "list_steps", "flatten_tree", "unflatten_into"]
