"""Checkpoint serialisation: pytree ↔ npz shards + JSON metadata.

Format: ``<dir>/step_<N>/arrays.npz`` (flattened path → array) and
``meta.json`` (step, config hash, mesh shape, rng, user metadata). Writes go
to a temp dir + atomic rename so a crash mid-write never corrupts the latest
checkpoint. In multi-process deployments each process writes
``arrays.<proc>.npz`` with its addressable shards; restore concatenates — the
single-process path (this container) exercises the same code with proc 0.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any
SEP = "|"


def flatten_tree(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                       for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def unflatten_into(template: Params, flat: Dict[str, np.ndarray]) -> Params:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                       for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} != expected {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_step(directory: str, step: int, tree: Params,
              meta: Optional[Dict] = None, *, process_index: int = 0) -> str:
    """``tree`` may be a pytree or an already-flattened {path: ndarray} dict."""
    if isinstance(tree, dict) and tree and all(
            isinstance(v, np.ndarray) for v in tree.values()):
        flat = tree
    else:
        flat = flatten_tree(tree)
    # npz can't store ml_dtypes (bfloat16, fp8): store a uint view + dtype tag
    dtypes = {}
    save = {}
    for k, arr in flat.items():
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            dtypes[k] = arr.dtype.name
            save[k] = arr.view({1: np.uint8, 2: np.uint16,
                                4: np.uint32}[arr.dtype.itemsize])
        else:
            save[k] = arr
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, f"arrays.{process_index}.npz"), **save)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            # reserved keys last: a caller round-tripping a restored meta
            # dict must never override the authoritative step/_dtypes
            json.dump({**(meta or {}), "step": step, "_dtypes": dtypes}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def load_meta(directory: str, step: int) -> Dict:
    """The ``meta.json`` of one checkpoint, without touching the arrays.

    Cheap by construction — resumable multi-stage jobs (repro.trajectory)
    must read the stage index / config identity *before* they can build the
    restore template, so meta has to be readable first.
    """
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        return json.load(f)


def load_step(directory: str, step: int) -> Tuple[Dict[str, np.ndarray], Dict]:
    d = os.path.join(directory, f"step_{step:08d}")
    flat: Dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(d)):
        if name.startswith("arrays.") and name.endswith(".npz"):
            with np.load(os.path.join(d, name)) as z:
                for k in z.files:
                    flat[k] = z[k]
    meta = load_meta(directory, step)
    for k, dt in meta.get("_dtypes", {}).items():
        import ml_dtypes  # noqa: F401 — registers bfloat16 & friends
        flat[k] = flat[k].view(np.dtype(dt))
    return flat, meta
